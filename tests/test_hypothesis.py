"""Property-based tests (hypothesis) for system invariants.

Skipped cleanly when `hypothesis` isn't installed (it's an optional test
dependency — `pip install -e .[test]`), so a bare environment still runs the
rest of the tier-1 suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ising, ladder, swap
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(2, 64), phase=st.integers(0, 5))
@settings(**SETTINGS)
def test_pairing_involution_property(n, phase):
    p = np.asarray(swap.pair_partners(n, phase))
    np.testing.assert_array_equal(p[p], np.arange(n))
    assert np.all(np.abs(p - np.arange(n)) <= 1)


@given(
    l=st.integers(2, 6).map(lambda k: 2 * k),  # checkerboard needs even L (PBC)
    seed=st.integers(0, 2**20),
    j=st.floats(-2, 2, allow_nan=False),
    b=st.floats(-1, 1, allow_nan=False),
)
@settings(**SETTINGS)
def test_sweep_energy_delta_property(l, seed, j, b):
    """For ANY even (L, J, B): incremental dE == recomputed energy difference
    and spins stay in {-1, +1}."""
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    spins = jnp.where(jax.random.uniform(k1, (2, l, l)) < 0.5, 1, -1).astype(jnp.int8)
    u = jax.random.uniform(k2, (2, 2, l, l))
    betas = jax.random.uniform(k3, (2,), minval=0.05, maxval=2.0)
    new, de, nacc = ref.ising_sweep(spins, u, betas, j=j, b=b)
    e0 = ising.lattice_energy(spins, j, b)
    e1 = ising.lattice_energy(new, j, b)
    np.testing.assert_allclose(np.asarray(e1 - e0), np.asarray(de), rtol=1e-4, atol=1e-2)
    assert set(np.unique(np.asarray(new))).issubset({-1, 1})
    assert (np.asarray(nacc) >= 0).all() and (np.asarray(nacc) <= 2 * l * l).all()


@given(seed=st.integers(0, 2**20), n=st.integers(2, 32))
@settings(**SETTINGS)
def test_swap_probability_bounds_and_symmetry(seed, n):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    betas = jnp.sort(jax.random.uniform(k1, (n,), minval=0.1, maxval=2.0))[::-1]
    e = jax.random.normal(k2, (n,)) * 50
    p = swap.swap_probability(betas[:-1], betas[1:], e[:-1], e[1:], "logistic")
    # relabel invariance: negating both factors keeps p unchanged
    q = swap.swap_probability(betas[1:], betas[:-1], e[1:], e[:-1], "logistic")
    # Barker complement: reversing only the energies complements p
    q2 = swap.swap_probability(betas[:-1], betas[1:], e[1:], e[:-1], "logistic")
    assert np.all((np.asarray(p) >= 0) & (np.asarray(p) <= 1))
    np.testing.assert_allclose(np.asarray(p), np.asarray(q), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p + q2), 1.0, rtol=1e-5)


@given(n=st.integers(2, 40))
@settings(**SETTINGS)
def test_paper_ladder_property(n):
    t = np.asarray(ladder.paper_ladder(n))
    assert abs(t[0] - 1.0) < 1e-6
    assert np.all(np.diff(t) > 0)
    np.testing.assert_allclose(np.diff(t), 3.0 / n, rtol=1e-5)
    assert t[-1] < 4.0  # paper's formula is exclusive at the hot end


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_wkv6_linearity_in_v(seed):
    """The recurrence is linear in v: wkv6(..., 2v) == 2*wkv6(..., v)."""
    key = jax.random.key(seed)
    ks = jax.random.split(key, 5)
    bh, t, dk, dv = 1, 12, 4, 4
    r = jax.random.normal(ks[0], (bh, t, dk))
    k = jax.random.normal(ks[1], (bh, t, dk))
    v = jax.random.normal(ks[2], (bh, t, dv))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, t, dk)))
    u = jax.random.normal(ks[4], (bh, dk))
    o1, s1 = ref.wkv6(r, k, v, w, u)
    o2, s2 = ref.wkv6(r, k, 2 * v, w, u)
    np.testing.assert_allclose(np.asarray(o2), 2 * np.asarray(o1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), 2 * np.asarray(s1), rtol=1e-5, atol=1e-5)
