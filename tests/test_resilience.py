"""Fault injection, supervised recovery, graceful degradation (DESIGN.md
§Resilience).

The contracts pinned here:

* **chaos invariant** — under any injected fault schedule, every served job
  either completes with results bit-equal to its fault-free run, or fails
  cleanly with a typed error — and the on-disk checkpoint directories stay
  restorable either way;
* **zero-cost-off** — with ``faults=None`` the `FaultPlan` class is never
  consulted (booby-trapped methods), and the mega-step jaxpr is
  byte-identical with a plan armed or absent;
* **supervision** — transient faults retry with deterministic backoff and
  recover bit-equal from the last intact checkpoint; exhausted retries (or
  a wedged watchdog) quarantine the bucket with a ``quarantine.json``
  manifest while bucket-mates in *other* buckets keep serving;
* **degradation** — a failed fused-kernel compile falls back to the
  per-sweep path (warning + counter), bit-equal to a never-fused run;
  ``strict_kernels`` makes it fatal;
* **lifecycle hygiene** — `Scheduler.shutdown` drains PENDING jobs into a
  typed `SchedulerStopped` failure, and a bounded intake queue rejects with
  `QueueFull` instead of accepting unbounded work.
"""
import json
import os
import warnings

import jax
import numpy as np
import pytest

from repro.api import (
    EngineSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    SystemSpec,
)
from repro.core.ising import IsingSystem
from repro.engine import Engine, EngineConfig
from repro.resilience import (
    SITES,
    BucketQuarantined,
    CompileTimeout,
    Fault,
    FaultPlan,
    InjectedCrash,
    InjectedFault,
    QuantumOutcome,
    RetryPolicy,
    Supervisor,
    WatchdogTimeout,
)
from repro.resilience.supervisor import QUARANTINE_NAME
from repro.serve import (
    JobFailedError,
    JobState,
    QueueFull,
    Scheduler,
    SchedulerStopped,
)


def serve_spec(seed=0, length=4, sweeps=(8, 8)) -> RunSpec:
    phases = [PhaseSpec("burn", sweeps[0])]
    if len(sweeps) > 1:
        phases.append(PhaseSpec("measure", sweeps[1], reset_stats=True))
    return RunSpec(
        system=SystemSpec("ising", {"length": length}),
        ladder=LadderSpec(kind="geometric", n_replicas=4, t_min=1.5, t_max=3.5),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2),
        schedule=ScheduleSpec(phases=tuple(phases)),
        observables=("mag",),
        seed=seed,
    )


def run_serve(faults=None, ckdir=None, n_jobs=3, **kw):
    """One scheduler pass over ``n_jobs`` seed-variant tenants."""
    kw.setdefault("retry_backoff_s", 0.001)
    sched = Scheduler(checkpoint_dir=ckdir, checkpoint_every_quanta=1,
                      faults=faults, **kw)
    handles = [
        sched.submit(serve_spec(seed=s), job_id=f"j{s}") for s in range(n_jobs)
    ]
    sched.run_until_idle()
    return sched, handles


@pytest.fixture(scope="module")
def baseline():
    """Fault-free reference results, one scheduler pass (module-cached)."""
    _, handles = run_serve()
    return {h.id: h.result(timeout=0) for h in handles}


def assert_bit_equal(result, ref):
    assert np.array_equal(
        np.asarray(result.final_energy), np.asarray(ref.final_energy)
    )
    assert set(result.phases) == set(ref.phases)
    for pname in ref.phases:
        for k, v in ref.phases[pname].items():
            assert np.array_equal(
                np.asarray(result.phases[pname][k]), np.asarray(v)
            ), (pname, k)


# -- FaultPlan semantics -------------------------------------------------------


def test_fault_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        Fault("engine.warp.core_breach")


def test_fault_plan_counts_occurrences_per_site():
    plan = FaultPlan([Fault("engine.chunk.launch", at=(1,))])
    assert plan.check("engine.chunk.launch") is None      # occurrence 0
    assert plan.check("engine.chunk.launch") is not None  # occurrence 1
    assert plan.check("engine.chunk.launch") is None      # occurrence 2
    assert plan.log == [("engine.chunk.launch", 1)]
    assert plan.fired() == 1
    assert plan.fired("engine.chunk.launch") == 1
    assert plan.fired("serve.callback") == 0


def test_fault_plan_fire_raises_typed():
    plan = FaultPlan([Fault("serve.callback", at=(0,))])
    with pytest.raises(InjectedFault):
        plan.fire("serve.callback")
    plan.fire("serve.callback")  # occurrence 1: disarmed


def test_fault_plan_from_seed_deterministic():
    a = FaultPlan.from_seed(7, n_faults=5)
    b = FaultPlan.from_seed(7, n_faults=5)
    assert a.faults == b.faults
    assert all(f.site in SITES for f in a.faults)
    assert FaultPlan.from_seed(8, n_faults=5).faults != a.faults


def test_fault_plan_on_fire_hook():
    seen = []
    plan = FaultPlan([Fault("engine.chunk.stall", at=(0,))],
                     on_fire=seen.append)
    plan.check("engine.chunk.stall")
    assert [f.site for f in seen] == ["engine.chunk.stall"]


# -- zero-cost-off (the obs-layer structural contract) -------------------------


def test_faults_off_never_consults_the_plan(monkeypatch, tmp_path):
    """With faults=None the FaultPlan class is never touched: booby-trap its
    methods and run the whole stack — engine, checkpoints, serve."""
    def bomb(*a, **k):
        raise AssertionError("faults-off path touched the FaultPlan layer")

    for meth in ("check", "fire", "__init__"):
        monkeypatch.setattr(FaultPlan, meth, bomb)
    sched = Scheduler(checkpoint_dir=str(tmp_path), checkpoint_every_quanta=1)
    h = sched.submit(serve_spec())
    sched.run_until_idle()
    assert h.result(timeout=0).n_sweeps == 16


def test_mega_step_jaxpr_identical_faults_on_and_off():
    temps = np.geomspace(1.5, 3.5, 4)
    cfg = EngineConfig(n_replicas=4, swap_interval=2, chunk_intervals=2)

    def jaxpr(faults):
        eng = Engine(IsingSystem(length=4), cfg, faults=faults)
        st = eng.init(jax.random.key(0), temps)
        return str(jax.make_jaxpr(eng._make_mega(2, st))(
            st.pt, st.stats, st.betas
        ))

    armed = FaultPlan([Fault(s) for s in sorted(SITES)])
    assert jaxpr(None) == jaxpr(armed)


# -- RetryPolicy / Supervisor unit behaviour -----------------------------------


def test_retry_policy_deterministic_and_bounded():
    p = RetryPolicy(max_attempts=5, base_delay_s=0.1, max_delay_s=1.0,
                    jitter=0.25)
    d1 = [p.delay("bucket-a", k) for k in range(1, 6)]
    assert d1 == [p.delay("bucket-a", k) for k in range(1, 6)]  # pure
    assert d1 != [p.delay("bucket-b", k) for k in range(1, 6)]  # decorrelated
    for k, d in enumerate(d1, start=1):
        base = min(1.0, 0.1 * 2 ** (k - 1))
        assert base <= d <= base * 1.25


def test_retry_policy_validates():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


class _FakeBucket:
    """Host-only bucket stub: fails its quantum ``failures`` times."""

    def __init__(self, failures, error=None, jobs=2):
        self.digest = "fake"
        self.name = "fake-0000"
        self.manager = None
        self.faults = None
        self.finished = False
        self.sweeps_done = 0
        self.restore_fallback_depth = 0
        self._failures = failures
        self._error = error or InjectedFault("boom")
        self._failed = set()
        self.jobs = [_FakeJob(f"f{i}") for i in range(jobs)]
        self.generation = 0

    def live_jobs(self):
        return [j for j in self.jobs if j.id not in self._failed]

    def run_quantum(self, chunks):
        if self._failures > 0:
            self._failures -= 1
            raise self._error
        self.finished = True
        return True

    def recover(self):
        fresh = _FakeBucket(self._failures, self._error)
        fresh.jobs = self.jobs
        fresh._failed = set(self._failed)
        fresh.generation = self.generation + 1
        return fresh

    def abandon(self):
        pass


class _FakeJob:
    def __init__(self, jid):
        self.id = jid
        self.state = JobState.RUNNING
        self.error = None

    def _fail(self, err):
        self.error = err
        self.state = JobState.FAILED


def test_supervisor_retries_then_succeeds():
    sup = Supervisor(policy=RetryPolicy(max_attempts=3, base_delay_s=0.0),
                     sleep=lambda s: None)
    out = sup.run(_FakeBucket(failures=2), 1)
    assert out.finished and not out.quarantined
    assert out.retries == 2
    assert out.bucket.generation == 2  # two recovered generations
    assert len(out.recoveries) == 2
    assert sup.totals["retries"] == 2


def test_supervisor_quarantines_after_max_attempts():
    sup = Supervisor(policy=RetryPolicy(max_attempts=2, base_delay_s=0.0),
                     sleep=lambda s: None)
    bucket = _FakeBucket(failures=99)
    out = sup.run(bucket, 1)
    assert out.quarantined and out.finished
    assert isinstance(out.error, InjectedFault)
    for job in out.bucket.jobs:
        assert job.state is JobState.FAILED
        assert isinstance(job.error, BucketQuarantined)
        assert isinstance(job.error.__cause__, InjectedFault)
    assert sup.totals["quarantined_buckets"] == 1
    assert sup.totals["quarantined_jobs"] == 2


def test_supervisor_wedged_watchdog_quarantines_immediately():
    sup = Supervisor(policy=RetryPolicy(max_attempts=5, base_delay_s=0.0),
                     sleep=lambda s: None)
    err = WatchdogTimeout("stuck", wedged=True)
    out = sup.run(_FakeBucket(failures=99, error=err), 1)
    assert out.quarantined
    assert out.retries == 0  # no retry raced against the stuck thread


def test_supervisor_backoff_uses_injected_sleep():
    slept = []
    sup = Supervisor(policy=RetryPolicy(max_attempts=3, base_delay_s=0.5),
                     sleep=slept.append)
    sup.run(_FakeBucket(failures=1), 1)
    assert len(slept) == 1 and slept[0] >= 0.5


# -- graceful kernel degradation -----------------------------------------------


def _engine_cfg():
    return EngineConfig(n_replicas=4, swap_interval=2, chunk_intervals=2)


def test_compile_failure_degrades_fused_to_per_sweep_bit_equal():
    temps = np.geomspace(1.5, 3.5, 4)
    plan = FaultPlan([Fault("engine.compile", at=(0,))])
    eng = Engine(IsingSystem(length=4, use_fused=True, use_pallas=True),
                 _engine_cfg(), faults=plan)
    with pytest.warns(RuntimeWarning, match="degrading to the per-sweep"):
        st = eng.init(jax.random.key(0), temps)
        st, _ = eng.run(st, 8)
    assert eng._degraded
    assert not eng.system.use_fused and not eng.system.use_pallas

    ref = Engine(IsingSystem(length=4), _engine_cfg())
    st2 = ref.init(jax.random.key(0), temps)
    st2, _ = ref.run(st2, 8)
    assert np.array_equal(np.asarray(st.pt.energy), np.asarray(st2.pt.energy))
    assert np.array_equal(np.asarray(st.pt.states), np.asarray(st2.pt.states))


def test_strict_kernels_makes_compile_failure_fatal():
    plan = FaultPlan([Fault("engine.compile", at=(0,))])
    eng = Engine(IsingSystem(length=4, use_fused=True), _engine_cfg(),
                 faults=plan, strict_kernels=True)
    st = eng.init(jax.random.key(0), np.geomspace(1.5, 3.5, 4))
    with pytest.raises(InjectedFault):
        eng.run(st, 8)


def test_plain_system_compile_failure_propagates():
    # nothing to degrade to: the supervisor owns this error class instead
    plan = FaultPlan([Fault("engine.compile", at=(0,))])
    eng = Engine(IsingSystem(length=4), _engine_cfg(), faults=plan)
    st = eng.init(jax.random.key(0), np.geomspace(1.5, 3.5, 4))
    with pytest.raises(InjectedFault):
        eng.run(st, 8)


def test_degraded_kernel_counter_increments():
    from repro.obs import Observability

    obs = Observability.create()
    plan = FaultPlan([Fault("engine.compile", at=(0,))])
    eng = Engine(IsingSystem(length=4, use_fused=True), _engine_cfg(),
                 faults=plan, obs=obs)
    st = eng.init(jax.random.key(0), np.geomspace(1.5, 3.5, 4))
    with pytest.warns(RuntimeWarning):
        eng.run(st, 8)
    snap = obs.metrics.snapshot()
    assert snap["pt_degraded_kernel"]["samples"][0]["value"] == 1.0


# -- supervised serve recovery -------------------------------------------------


def test_transient_faults_recover_bit_equal(tmp_path, baseline):
    plan = FaultPlan([
        Fault("engine.chunk.launch", at=(1, 5)),
        Fault("checkpoint.write.torn", at=(0,)),
    ])
    sched, handles = run_serve(faults=plan, ckdir=str(tmp_path))
    assert plan.fired() >= 2
    assert sched._supervisor.totals["retries"] >= 1
    for h in handles:
        assert_bit_equal(h.result(timeout=0), baseline[h.id])


def test_quarantine_writes_manifest_and_fails_jobs_typed(tmp_path):
    plan = FaultPlan([Fault("engine.chunk.launch", at=tuple(range(64)))])
    sched, handles = run_serve(faults=plan, ckdir=str(tmp_path),
                               max_attempts=2)
    for h in handles:
        with pytest.raises(JobFailedError) as ei:
            h.result(timeout=0)
        assert isinstance(ei.value.__cause__, BucketQuarantined)
    manifests = [
        os.path.join(tmp_path, n, QUARANTINE_NAME)
        for n in os.listdir(tmp_path)
        if os.path.isfile(os.path.join(tmp_path, n, QUARANTINE_NAME))
    ]
    assert len(manifests) == 1
    man = json.load(open(manifests[0]))
    assert man["attempts"] == 2
    assert sorted(man["jobs"]) == ["j0", "j1", "j2"]
    assert man["fired_faults"]  # the schedule that killed it is recorded
    assert sched.stats()["resilience"]["quarantined_jobs"] == 3


def test_nonfinite_energy_fails_only_the_owning_tenant(baseline):
    plan = FaultPlan([Fault("engine.energy.nonfinite", at=(0,), chain=1)])
    _, handles = run_serve(faults=plan)
    by_id = {h.id: h for h in handles}
    assert by_id["j1"].state is JobState.FAILED
    assert isinstance(by_id["j1"].error, FloatingPointError)
    for jid in ("j0", "j2"):
        assert by_id[jid].state is JobState.DONE
        assert_bit_equal(by_id[jid].result(timeout=0), baseline[jid])


def test_callback_fault_is_isolated_per_job(baseline):
    plan = FaultPlan([Fault("serve.callback", at=(1,))])
    _, handles = run_serve(faults=plan)
    failed = [h for h in handles if h.state is JobState.FAILED]
    assert len(failed) == 1
    assert isinstance(failed[0].error, InjectedFault)
    for h in handles:
        if h.state is JobState.DONE:
            assert_bit_equal(h.result(timeout=0), baseline[h.id])


def test_watchdog_recovers_stalled_quantum_bit_equal(tmp_path, baseline):
    plan = FaultPlan([Fault("engine.chunk.stall", at=(1,), duration=10.0)])
    sched, handles = run_serve(faults=plan, ckdir=str(tmp_path),
                               watchdog_s=1.0)
    sched._supervisor.grace_s = 30.0
    assert sched._supervisor.totals["retries"] >= 1
    for h in handles:
        assert_bit_equal(h.result(timeout=0), baseline[h.id])


def test_injected_checkpoint_crash_does_not_kill_the_host_loop(
    tmp_path, baseline
):
    plan = FaultPlan([Fault("checkpoint.write.crash_before_rename", at=(0,)),
                      Fault("checkpoint.write.crash_after_rename", at=(1,))])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        _, handles = run_serve(faults=plan, ckdir=str(tmp_path))
    assert plan.fired() == 2
    for h in handles:
        assert_bit_equal(h.result(timeout=0), baseline[h.id])


def test_resilience_metrics_recorded(tmp_path):
    plan = FaultPlan([Fault("engine.chunk.launch", at=(1,))])
    sched, _ = run_serve(faults=plan, ckdir=str(tmp_path))
    snap = sched.metrics()
    fired = {
        tuple(s["labels"].values()): s["value"]
        for s in snap["pt_fault_injected"]["samples"]
    }
    assert fired[("engine.chunk.launch",)] == 1.0
    assert snap["pt_retries"]["samples"][0]["value"] >= 1.0
    assert snap["pt_quarantined"]["samples"] == [] or (
        snap["pt_quarantined"]["samples"][0]["value"] == 0.0
    )


# -- the chaos suite -----------------------------------------------------------


def _chaos_seeds():
    env = os.environ.get("CHAOS_SEEDS", "")
    if env:
        return [int(s) for s in env.replace(",", " ").split()]
    return [0, 1, 2]


def _assert_checkpoints_intact(root):
    """Corruption on disk is always *detected*: every surviving generation
    either verifies against its digest manifest or raises the typed
    `CheckpointCorrupt` that makes `restore_latest` skip it — nothing can
    silently unflatten into garbage at restore time."""
    from repro.checkpoint.manager import CheckpointCorrupt, CheckpointManager

    for name in os.listdir(root):
        sub = os.path.join(root, name)
        if not os.path.isdir(sub):
            continue
        m = CheckpointManager(sub)
        for step in m.steps():
            try:
                m._verify(step)
            except CheckpointCorrupt:
                pass  # an injected torn/flipped write, caught typed


@pytest.mark.parametrize("seed", _chaos_seeds())
def test_chaos_invariant(seed, tmp_path, baseline):
    """The headline invariant: under a seeded random fault schedule every
    job completes bit-equal to its fault-free run OR fails with a typed
    error, and the checkpoint directory survives restorable."""
    plan = FaultPlan.from_seed(seed, n_faults=4)
    sched, handles = run_serve(faults=plan, ckdir=str(tmp_path),
                               max_attempts=3)
    for h in handles:
        if h.state is JobState.DONE:
            assert_bit_equal(h.result(timeout=0), baseline[h.id])
        else:
            assert h.state is JobState.FAILED
            assert isinstance(
                h.error,
                (InjectedFault, InjectedCrash, BucketQuarantined,
                 FloatingPointError, WatchdogTimeout),
            ), repr(h.error)
    _assert_checkpoints_intact(tmp_path)


def test_chaos_schedule_property_on_supervisor():
    """Hypothesis-driven schedules over the supervisor state machine: any
    mix of transient failures and wedges ends finished-or-quarantined, with
    every failed job's error typed."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(
        failures=st.integers(0, 6),
        max_attempts=st.integers(1, 4),
        wedged=st.booleans(),
    )
    def check(failures, max_attempts, wedged):
        err = (WatchdogTimeout("stuck", wedged=True) if wedged
               else InjectedFault("boom"))
        sup = Supervisor(
            policy=RetryPolicy(max_attempts=max_attempts, base_delay_s=0.0),
            sleep=lambda s: None,
        )
        out = sup.run(_FakeBucket(failures=failures, error=err), 1)
        assert out.finished or not out.quarantined
        if failures == 0:
            assert not out.quarantined and out.retries == 0
        elif wedged or failures >= max_attempts:
            assert out.quarantined
            for job in out.bucket.jobs:
                assert isinstance(job.error, BucketQuarantined)
        else:
            assert not out.quarantined
            assert out.retries == failures

    check()


# -- lifecycle hygiene ---------------------------------------------------------


def test_shutdown_drains_pending_jobs_typed():
    sched = Scheduler()
    h = sched.submit(serve_spec())
    sched.shutdown()  # loop never ran: the job would block forever pre-fix
    with pytest.raises(JobFailedError) as ei:
        h.result(timeout=0)
    assert isinstance(ei.value.__cause__, SchedulerStopped)


def test_shutdown_drains_staged_jobs_typed():
    sched = Scheduler(pack_window=3600.0)  # stage, never seal
    h = sched.submit(serve_spec())
    sched._intake()
    assert len(sched.queue) == 0 and sched._staged
    sched.shutdown()
    assert h.state is JobState.FAILED
    assert isinstance(h.error, SchedulerStopped)


def test_started_shutdown_no_wait_fails_pending(tmp_path):
    sched = Scheduler(pack_window=3600.0)
    sched.start()
    h = sched.submit(serve_spec())
    sched.shutdown(wait=False)
    assert h.state is JobState.FAILED
    assert isinstance(h.error, SchedulerStopped)


def test_queue_depth_backpressure():
    sched = Scheduler(queue_depth=2)
    sched.submit(serve_spec(seed=0))
    sched.submit(serve_spec(seed=1))
    with pytest.raises(QueueFull):
        sched.submit(serve_spec(seed=2))
    # the rejected submission registered nothing
    assert len(sched.jobs) == 2
    with pytest.raises(QueueFull):
        sched.submit(serve_spec(seed=2), block=True, timeout=0.05)


def test_result_timeout_raises_instead_of_hanging():
    sched = Scheduler()
    h = sched.submit(serve_spec())
    with pytest.raises(TimeoutError, match="still pending"):
        h.result(timeout=0.01)
    sched.run_until_idle()
    assert h.result(timeout=0).n_sweeps == 16


# -- restart with faults threaded through -------------------------------------


def test_from_checkpoint_skips_poisoned_bucket(tmp_path, baseline):
    from repro.serve.bucket import MANIFEST_NAME

    sched = Scheduler(checkpoint_dir=str(tmp_path), checkpoint_every_quanta=1)
    h = sched.submit(serve_spec(), job_id="j0")
    for _ in range(2):
        sched.step()
    assert not h.done()
    bad = os.path.join(tmp_path, "deadbeef-0099")
    os.makedirs(bad)
    with open(os.path.join(bad, MANIFEST_NAME), "w") as f:
        f.write("{ not json")
    with pytest.warns(RuntimeWarning, match="unreadable bucket manifest"):
        sched2 = Scheduler.from_checkpoint(str(tmp_path))
    sched2.run_until_idle()
    assert sched2.jobs["j0"].state is JobState.DONE
    # phases completed before the restore survive via the checkpoint cut;
    # final energies are always bit-equal to the fault-free run
    assert np.array_equal(
        np.asarray(sched2.jobs["j0"].result(timeout=0).final_energy),
        np.asarray(baseline["j0"].final_energy),
    )
