"""Unit tests for the validation layer itself (`repro.validate`).

The conformance suite trusts `validate.exact` as ground truth, so this file
pins the ground truth against *independent* computations: tiny-lattice
enumerations re-done in-test with the systems' own jax energy functions,
closed-form limits (two-level systems, single-Gaussian moments, infinite-
temperature averages), and known SAW counts.  The MCSE/ESS/Geweke machinery
is checked on iid data where every answer is analytic.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gaussian, hp, ising, potts, spin_glass
from repro.validate import exact as ex
from repro.validate.mcse import batch_mean_stats, effective_sample_size, geweke_z

TEMPS = np.asarray([0.8, 1.7, 3.1])


# ---------- boltzmann_means ------------------------------------------------------
def test_boltzmann_means_two_level_system():
    """E in {0, d}: <E> = d / (1 + e^{d/T}) — textbook two-level formula."""
    d = 1.3
    got = ex.boltzmann_means(np.asarray([0.0, d]), {}, TEMPS)["energy"]
    want = d / (1.0 + np.exp(d / TEMPS))
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_boltzmann_means_observable_weighting():
    e = np.asarray([0.0, 2.0])
    obs = np.asarray([1.0, -1.0])
    got = ex.boltzmann_means(e, {"o": obs}, TEMPS)
    w = np.exp(-2.0 / TEMPS)
    np.testing.assert_allclose(got["o"], (1.0 - w) / (1.0 + w), rtol=1e-12)


# ---------- lattice enumerations vs the systems' own energy functions ------------
def test_ising_exact_matches_jax_energy_enumeration():
    system = ising.IsingSystem(length=2)
    configs = ex._spin_configs(4).reshape(-1, 2, 2)
    e = np.asarray(jax.vmap(system.energy)(jnp.asarray(configs)))
    absm = np.abs(configs.reshape(-1, 4).mean(axis=1))
    want = ex.boltzmann_means(e, {"absmag": absm}, TEMPS)
    got = ex.ising_exact(system, TEMPS)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-10, err_msg=k)


def test_ea_exact_matches_jax_energy_enumeration():
    system = spin_glass.EASpinGlass(shape=(2, 2), disorder_seed=3)
    jr, jd = system.disorder()
    configs = ex._spin_configs(4).reshape(-1, 2, 2)
    states = {
        "spins": jnp.asarray(configs),
        "jr": jnp.broadcast_to(jr, (16, 2, 2)),
        "jd": jnp.broadcast_to(jd, (16, 2, 2)),
    }
    e = np.asarray(jax.vmap(spin_glass.ea_energy)(states))
    absm = np.abs(configs.reshape(-1, 4).mean(axis=1))
    want = ex.boltzmann_means(e, {"absmag": absm}, TEMPS)
    got = ex.ea_exact(system, TEMPS)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-10, err_msg=k)


def test_potts_exact_matches_jax_energy_enumeration():
    system = potts.PottsSystem(shape=(2, 2), q=3)
    configs = np.asarray(
        list(itertools.product(range(3), repeat=4)), np.int8
    ).reshape(-1, 2, 2)
    e = np.asarray(jax.vmap(lambda s: system.energy(s))(jnp.asarray(configs)))
    m = np.asarray(
        jax.vmap(lambda s: potts.potts_magnetization(s, 3))(jnp.asarray(configs))
    )
    want = ex.boltzmann_means(e, {"pmag": m}, TEMPS)
    got = ex.potts_exact(system, TEMPS)
    np.testing.assert_allclose(got["energy"], want["energy"], rtol=1e-10)
    np.testing.assert_allclose(got["pmag"], want["pmag"], rtol=1e-6)


def test_potts_exact_chunking_invariant():
    """Chunked enumeration must not depend on the chunk size."""
    system = potts.PottsSystem(shape=(2, 2), q=3)
    a = ex.potts_exact(system, TEMPS, chunk=7)
    b = ex.potts_exact(system, TEMPS, chunk=81)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-12)


# ---------- gaussian: quadrature vs closed form ----------------------------------
def test_gaussian_exact_matches_single_component_analytics():
    sig = 1.3
    system = gaussian.GaussianMixture(mus=(0.0,), sigmas=(sig,), weights=(1.0,))
    got = ex.gaussian_exact(system, TEMPS)
    betas = 1.0 / TEMPS
    want_e = 0.5 / betas + np.log(sig * np.sqrt(2 * np.pi))
    want_absx = (sig / np.sqrt(betas)) * np.sqrt(2 / np.pi)
    np.testing.assert_allclose(got["energy"], want_e, rtol=1e-6)
    np.testing.assert_allclose(got["absx"], want_absx, rtol=1e-6)


# ---------- HP: SAW enumeration, limits, ergodicity ------------------------------
def test_enumerate_saws_known_counts():
    for n_steps, count in [(1, 4), (2, 12), (3, 36), (4, 100), (5, 284)]:
        assert len(ex.enumerate_saws(n_steps)) == count


def test_hp_exact_infinite_temperature_is_uniform_average():
    system = hp.HPChain(sequence="HPHPPH")
    pos = ex.enumerate_saws(5)
    e = np.asarray(jax.vmap(system.energy)(jnp.asarray(pos, jnp.int32)))
    rg2 = np.asarray(
        jax.vmap(hp.radius_of_gyration_sq)(jnp.asarray(pos, jnp.int32))
    )
    got = ex.hp_exact(system, np.asarray([1e8]))
    np.testing.assert_allclose(got["energy"][0], e.mean(), rtol=1e-5)
    np.testing.assert_allclose(got["rg2"][0], rg2.mean(), rtol=1e-5)


def test_hp_exact_zero_temperature_reaches_ground_state():
    system = hp.HPChain(sequence="HPHPPH")
    pos = ex.enumerate_saws(5)
    e = np.asarray(jax.vmap(system.energy)(jnp.asarray(pos, jnp.int32)))
    got = ex.hp_exact(system, np.asarray([1e-3]))
    np.testing.assert_allclose(got["energy"][0], e.min(), atol=1e-6)


def test_hp_move_graph_connected_small_chain():
    assert ex.hp_move_graph_connected(5)


# ---------- MCSE / ESS / Geweke on iid data --------------------------------------
def test_batch_mean_stats_iid(rng):
    m, l = 64, 200
    x = rng.normal(loc=2.0, scale=3.0, size=(m, l))
    mean, mcse, n = batch_mean_stats(x.mean(axis=1))
    assert n == m
    np.testing.assert_allclose(mean, 2.0, atol=4 * 3.0 / np.sqrt(m * l))
    np.testing.assert_allclose(mcse, 3.0 / np.sqrt(m * l), rtol=0.35)


def test_effective_sample_size_iid(rng):
    m, l = 64, 200
    x = rng.normal(size=(m, l))
    _, mcse, _ = batch_mean_stats(x.mean(axis=1))
    ess = effective_sample_size(x.var(ddof=1), mcse)
    assert 0.5 * m * l < float(ess) < 2.0 * m * l  # iid: ESS ~ sample count
    assert float(effective_sample_size(0.0, 0.0)) == 0.0


def test_batch_mean_stats_rejects_single_batch():
    with pytest.raises(ValueError, match="M >= 2"):
        batch_mean_stats(np.ones((1, 3)))


def test_geweke_z_detects_drift(rng):
    same = geweke_z(rng.normal(size=(40,)), rng.normal(size=(40,)))
    drift = geweke_z(rng.normal(size=(40,)), rng.normal(loc=5.0, size=(40,)))
    assert abs(float(same)) < 4.0
    assert abs(float(drift)) > 10.0
