"""PT-LM sampling tests: proposal correctness, energy bookkeeping, mixing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ladder, pt
from repro.core.ptlm import LMSystem
from repro.models import model as model_lib


def _system(seq_len=12):
    cfg = get_config("gemma_2b", reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    return LMSystem(cfg=cfg, seq_len=seq_len).bind(params), cfg


def test_energy_is_sequence_nll():
    system, cfg = _system()
    tokens = jax.random.randint(jax.random.key(1), (3, 12), 0, cfg.vocab)
    e = system.batched_energy(tokens)
    assert e.shape == (3,)
    assert np.isfinite(np.asarray(e)).all()
    # sequence NLL past the prompt: at random init ~ (S-1) * log V scale
    assert np.all(np.asarray(e) > 0)


def test_mcmc_step_changes_at_most_one_token():
    system, cfg = _system()
    tokens = jax.random.randint(jax.random.key(2), (4, 12), 0, cfg.vocab)
    keys = jax.random.split(jax.random.key(3), 4)
    new, de, acc = system.batched_mcmc_step(keys, tokens, jnp.ones((4,)))
    diff = (np.asarray(new) != np.asarray(tokens)).sum(axis=1)
    assert np.all(diff <= 1)
    # delta-e must be exact vs recomputation
    e0 = np.asarray(system.batched_energy(tokens))
    e1 = np.asarray(system.batched_energy(new))
    np.testing.assert_allclose(e1 - e0, np.asarray(de), rtol=1e-4, atol=5e-3)


def test_pt_run_improves_cold_chain_nll():
    system, cfg = _system()
    R = 4
    temps = tuple(float(t) for t in ladder.geometric_ladder(R, 1.0, 8.0))
    ptc = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=5, swap_mode="temp")
    st = pt.init(system, ptc, jax.random.key(4))
    inv0 = np.argsort(np.asarray(st.rung))
    e0 = float(np.asarray(st.energy)[inv0][0])
    st2, trace = pt.run(system, ptc, st, 60)
    e_cold = float(np.asarray(trace["energy"])[-1, 0])
    assert np.isfinite(e_cold)
    assert e_cold < e0, (e0, e_cold)  # sampler should find likelier sequences
    # energies track recomputation across swaps/moves
    direct = np.asarray(system.batched_energy(st2.states))
    np.testing.assert_allclose(np.asarray(st2.energy), direct, rtol=1e-4, atol=5e-3)
