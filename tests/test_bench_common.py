"""`benchmarks.common` record accumulation: drain-on-write semantics.

A suite run twice in one process must produce two clean BENCH_<group>.json
files — the accumulator drains after a successful write — while a *failed*
write keeps the rows so the caller can retry without losing them.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, ".")  # repo root: benchmarks is a plain package

from benchmarks.common import _RECORDS, emit, write_bench_json  # noqa: E402


@pytest.fixture(autouse=True)
def clean_records():
    _RECORDS.clear()
    yield
    _RECORDS.clear()


def test_write_drains_the_group(tmp_path, capsys):
    emit("row_a", 1.5e-6, "d=1", group="g1", metrics={"sweeps": 4})
    emit("row_b", 2.5e-6, group="g1")
    emit("other", 1e-6, group="g2")
    path = write_bench_json("g1", str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert [r["name"] for r in payload["records"]] == ["row_a", "row_b"]
    assert payload["records"][0]["metrics"] == {"sweeps": 4.0}
    # g1 drained, g2 untouched
    assert "g1" not in _RECORDS
    assert [r["name"] for r in _RECORDS["g2"]] == ["other"]
    # a second suite pass in the same process starts from zero records
    emit("row_c", 3.0e-6, group="g1")
    with open(write_bench_json("g1", str(tmp_path))) as f:
        second = json.load(f)
    assert [r["name"] for r in second["records"]] == ["row_c"]


def test_write_without_records_produces_empty_file(tmp_path):
    with open(write_bench_json("empty", str(tmp_path))) as f:
        payload = json.load(f)
    assert payload["records"] == []
    assert payload["group"] == "empty"


def test_failed_write_retains_rows(tmp_path):
    emit("keep_me", 1e-6, group="g3")
    target = tmp_path / "blocked"
    target.write_text("a file where the out dir should be")
    with pytest.raises(OSError):
        write_bench_json("g3", str(target / "sub"))
    # the failed write must NOT have drained the accumulator
    assert [r["name"] for r in _RECORDS["g3"]] == ["keep_me"]
    path = write_bench_json("g3", str(tmp_path))
    with open(path) as f:
        assert [r["name"] for r in json.load(f)["records"]] == ["keep_me"]


def test_write_is_atomic_no_tmp_left_behind(tmp_path):
    emit("row", 1e-6, group="g4")
    write_bench_json("g4", str(tmp_path))
    assert sorted(os.listdir(tmp_path)) == ["BENCH_g4.json"]
