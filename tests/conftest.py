"""Shared test configuration and property-test strategies.

NOTE: tests run on the single real CPU device — the 512-device flag is set
*only* inside `repro/launch/dryrun.py` (per DESIGN.md §7); never here.

The bottom half defines the **shared hypothesis strategies** used by
`test_hypothesis.py` and the conformance/property suites (ladders, lattice
shapes, system configs), so individual test modules stop hand-rolling
generators.  Everything hypothesis-dependent is guarded: a bare environment
without the optional `hypothesis` dependency still runs the rest of tier-1
(tests gate themselves with ``pytest.importorskip("hypothesis")``).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# -- shared hypothesis strategies ----------------------------------------------
try:
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only in bare environments
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def temp_ladders(draw, min_rungs=2, max_rungs=16):
        """Strictly increasing cold->hot ladder as a float tuple.

        Built from a cold endpoint plus positive log-gaps, which covers
        linear-ish, geometric-ish and badly skewed ladders alike.
        """
        r = draw(st.integers(min_rungs, max_rungs))
        t0 = draw(st.floats(0.3, 2.0, allow_nan=False, allow_infinity=False))
        gaps = draw(
            st.lists(st.floats(0.01, 0.8), min_size=r - 1, max_size=r - 1)
        )
        temps = np.exp(np.cumsum([np.log(t0)] + gaps))
        return tuple(float(t) for t in temps)

    @st.composite
    def lattice_shapes(draw, even=True, min_side=2, max_side=12):
        """(H, W) lattice shape; ``even=True`` keeps PBC 2-colourability."""
        side = st.integers(min_side, max_side)
        h, w = draw(side), draw(side)
        if even:
            h, w = 2 * ((h + 1) // 2), 2 * ((w + 1) // 2)
        return (h, w)

    @st.composite
    def ising_systems(draw):
        """Checkerboard-capable IsingSystem configs (construction deferred)."""
        from repro.core.ising import IsingSystem

        h, _ = draw(lattice_shapes(min_side=2, max_side=6))
        return IsingSystem(
            length=h,
            j=draw(st.floats(-2, 2, allow_nan=False)),
            b=draw(st.floats(-1, 1, allow_nan=False)),
            accept_rule=draw(st.sampled_from(["metropolis", "glauber"])),
        )

    @st.composite
    def potts_systems(draw):
        from repro.core.potts import PottsSystem

        return PottsSystem(
            shape=draw(lattice_shapes(min_side=2, max_side=6)),
            q=draw(st.integers(2, 5)),
            j=draw(st.floats(-2, 2, allow_nan=False)),
            accept_rule=draw(st.sampled_from(["metropolis", "glauber"])),
        )

    @st.composite
    def rung_energies(draw, n):
        """(n,) float32 energy vector with PT-realistic spread."""
        vals = draw(st.lists(st.floats(-60, 60, width=32), min_size=n, max_size=n))
        return np.asarray(vals, np.float32)

    @st.composite
    def exchange_strategies(draw, names=None):
        """A registered replica-exchange strategy instance (any family).

        Windowed strategies draw their window size too, so the involution
        and in-window-distance properties get exercised across window
        configurations — the same pool `test_exchange.py` and the
        conformance matrix build on.
        """
        from repro.exchange import available_strategies, make_strategy

        name = draw(st.sampled_from(sorted(names or available_strategies())))
        params = {}
        if name == "windowed":
            params["window"] = draw(st.integers(2, 7))
        return make_strategy(name, params)
