"""Shared test configuration.

NOTE: tests run on the single real CPU device — the 512-device flag is set
*only* inside `repro/launch/dryrun.py` (per DESIGN.md §7); never here.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
