"""PT-as-a-service (DESIGN.md §Serve): packing isolation, preemption,
fairness, failure containment.

The contracts pinned here:

* **bit-equality** — a packed tenant's streamed energies, phase summaries
  and final state are bitwise identical to running its spec alone (packing
  changes throughput, never results);
* **one compile** — N same-shaped jobs share exactly one mega-step compile
  (`Engine.n_compiles`), and bucket generation N+1 reuses generation N's
  engine;
* **preemption** — any quantum slicing, and a full process "crash" +
  `Scheduler.from_checkpoint`, resume bit-equal to an uninterrupted run;
* **fairness** — strict round-robin: no bucket starves while another runs;
* **isolation** — a failing tenant (callback raise) FAILs alone; its
  bucket-mates finish with untouched results.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    AdaptSpec,
    EngineSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    Session,
    SystemSpec,
)
from repro.serve import (
    JobFailedError,
    JobState,
    Scheduler,
    check_servable,
    shape_signature,
)


def serve_spec(seed=0, length=4, n_chains=1, record_trace=False,
               sweeps=(8, 8)) -> RunSpec:
    phases = [PhaseSpec("burn", sweeps[0])]
    if len(sweeps) > 1:
        phases.append(PhaseSpec("measure", sweeps[1], reset_stats=True))
    return RunSpec(
        system=SystemSpec("ising", {"length": length}),
        ladder=LadderSpec(kind="geometric", n_replicas=4, t_min=1.5, t_max=3.5),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2,
                          n_chains=n_chains, record_trace=record_trace),
        schedule=ScheduleSpec(phases=tuple(phases)),
        observables=("mag",),
        seed=seed,
    )


def solo(spec):
    return Session(spec).run()


def assert_job_matches_solo(result, spec):
    ref = solo(spec)
    assert np.array_equal(
        np.asarray(result.final_energy), ref.final_energies()
    )
    for pname, res in ref.phases.items():
        assert pname in result.phases
        for k, v in res.summary.items():
            assert np.array_equal(
                np.asarray(result.phases[pname][k]), np.asarray(v)
            ), (pname, k)


# -- signature / servability ---------------------------------------------------


def test_shape_signature_ignores_only_the_seed():
    a, b = serve_spec(seed=0), serve_spec(seed=123)
    assert shape_signature(a)[0] == shape_signature(b)[0]
    for variant in (
        serve_spec(length=6),
        dataclasses.replace(
            serve_spec(), ladder=LadderSpec(
                kind="geometric", n_replicas=4, t_min=1.4, t_max=3.5
            )
        ),
        serve_spec(n_chains=2),
        serve_spec(sweeps=(8, 16)),
    ):
        assert shape_signature(a)[0] != shape_signature(variant)[0]
    assert "seed" not in shape_signature(a)[1]


def test_check_servable_rejects_adapt_and_mesh():
    adaptive = dataclasses.replace(
        serve_spec(),
        adapt=AdaptSpec(),
        schedule=ScheduleSpec(phases=(
            PhaseSpec("burn", 8, adapt=True), PhaseSpec("measure", 8),
        )),
    )
    with pytest.raises(ValueError, match="adapt"):
        check_servable(adaptive)
    meshed = dataclasses.replace(
        serve_spec(),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2,
                          mesh={"ensemble": 1, "replica": 1}),
    )
    with pytest.raises(ValueError, match="mesh"):
        check_servable(meshed)
    # submit-side rejection fails the job, not the scheduler
    sched = Scheduler()
    job = sched.submit(adaptive)
    sched.run_until_idle()
    assert job.state is JobState.FAILED
    with pytest.raises(JobFailedError):
        job.result(timeout=0)


# -- packing bit-equality ------------------------------------------------------


def test_packed_jobs_bit_equal_to_solo_with_one_compile():
    sched = Scheduler(quantum_chunks=1)
    streamed = {}

    def record(job, update):
        streamed.setdefault(job.id, []).append(update)

    seeds = (0, 1, 7)
    handles = [
        sched.submit(serve_spec(seed=s), on_update=record) for s in seeds
    ]
    sched.run_until_idle()
    stats = sched.stats()
    assert stats["n_compiles"] == 1  # 3 tenants, one mega-step executable
    assert stats["n_engines"] == 1
    for job, seed in zip(handles, seeds):
        assert job.state is JobState.DONE
        assert_job_matches_solo(job.result(timeout=5), serve_spec(seed=seed))


def test_streamed_observables_bit_equal_to_solo_chunks():
    """Every per-chunk JobUpdate matches the solo run's ChunkInfo stream."""
    from repro.api import Callback

    class Capture(Callback):
        def __init__(self):
            self.energies = []

        def on_chunk(self, session, info):
            e = np.asarray(info.state.pt.energy)
            r = np.asarray(info.state.pt.rung)
            self.energies.append(e[np.argsort(r)].copy())

    sched = Scheduler(quantum_chunks=1)
    streamed = {}

    def record(job, update):
        streamed.setdefault(job.id, []).append(update.energy)

    seeds = (3, 4)
    handles = [
        sched.submit(serve_spec(seed=s), on_update=record) for s in seeds
    ]
    sched.run_until_idle()
    for job, seed in zip(handles, seeds):
        cap = Capture()
        Session(serve_spec(seed=seed), callbacks=[cap]).run()
        packed = streamed[job.id]
        assert len(packed) == len(cap.energies)
        for got, want in zip(packed, cap.energies):
            assert np.array_equal(got, want)


def test_multi_chain_and_trace_tenants_pack_bit_equal():
    sched = Scheduler()
    spec_a = serve_spec(seed=11, n_chains=2, record_trace=True)
    spec_b = serve_spec(seed=12, n_chains=1, record_trace=True)
    traces = {}

    def record(job, update):
        if update.trace is not None:
            traces.setdefault(job.id, []).append(update.trace)

    ja = sched.submit(spec_a, on_update=record)
    jb = sched.submit(spec_b, on_update=record)
    sched.run_until_idle()
    # different n_chains -> different signatures -> separate buckets
    assert sched.stats()["n_engines"] == 2
    assert_job_matches_solo(ja.result(timeout=5), spec_a)
    assert_job_matches_solo(jb.result(timeout=5), spec_b)
    # streamed trace slices concatenate to the solo run's full trace
    for job, spec in ((ja, spec_a), (jb, spec_b)):
        ref = solo(spec)
        axis = 1 if spec.engine.n_chains > 1 else 0
        full = {
            k: np.concatenate([t[k] for t in traces[job.id]], axis=axis)
            for k in traces[job.id][0]
        }
        # phases run back-to-back on one state: solo stores per-phase traces
        ref_full = {
            k: np.concatenate(
                [ref.phases[p.name].trace[k] for p in spec.schedule.phases],
                axis=axis,
            )
            for k in full
        }
        for k in ref_full:
            assert np.array_equal(full[k], ref_full[k]), (job.id, k)


def test_engine_cache_reused_across_bucket_generations():
    sched = Scheduler()
    first = sched.submit(serve_spec(seed=0))
    sched.run_until_idle()
    second = sched.submit(serve_spec(seed=99))  # same shape, new bucket
    sched.run_until_idle()
    stats = sched.stats()
    assert stats["n_engines"] == 1
    assert stats["n_compiles"] == 1  # generation 2 reused the executable
    assert_job_matches_solo(second.result(timeout=5), serve_spec(seed=99))
    assert first.result(timeout=0).job_id == first.id


# -- preemption ----------------------------------------------------------------


@pytest.mark.parametrize("quantum_chunks", [1, 3])
def test_preemption_slicing_is_invisible(quantum_chunks):
    """Any quantum size yields bit-identical results (chunk boundaries are
    invisible to the PRNG stream)."""
    sched = Scheduler(quantum_chunks=quantum_chunks)
    spec = serve_spec(seed=5, sweeps=(8, 16))
    job = sched.submit(spec)
    sched.run_until_idle()
    assert_job_matches_solo(job.result(timeout=5), spec)


def test_crash_restart_resumes_bit_equal(tmp_path):
    seeds = (0, 2)
    make = lambda s: serve_spec(seed=s, sweeps=(8, 16))
    sched = Scheduler(checkpoint_dir=str(tmp_path), quantum_chunks=1,
                      checkpoint_every_quanta=1)
    for s in seeds:
        sched.submit(make(s), job_id=f"j{s}")
    sched.run_until_idle(max_quanta=2)  # preempt mid-schedule, then "crash"
    assert all(
        sched.jobs[f"j{s}"].state is JobState.PREEMPTED for s in seeds
    )
    resumed = Scheduler.from_checkpoint(
        str(tmp_path), quantum_chunks=1, checkpoint_every_quanta=1
    )
    assert sorted(resumed.jobs) == [f"j{s}" for s in seeds]
    resumed.run_until_idle()
    for s in seeds:
        res = resumed.result(f"j{s}", timeout=5)
        ref = solo(make(s))
        assert np.array_equal(np.asarray(res.final_energy), ref.final_energies())
        # the measure phase ends after the restore point -> present, bit-equal
        for k, v in ref.phases["measure"].summary.items():
            assert np.array_equal(
                np.asarray(res.phases["measure"][k]), np.asarray(v)
            ), k


def test_restart_of_finished_bucket_delivers_immediately(tmp_path):
    sched = Scheduler(checkpoint_dir=str(tmp_path))
    sched.submit(serve_spec(seed=1), job_id="done-job")
    sched.run_until_idle()
    resumed = Scheduler.from_checkpoint(str(tmp_path))
    assert resumed.result("done-job", timeout=0).n_sweeps == 16
    assert resumed.idle()


# -- fairness ------------------------------------------------------------------


def test_round_robin_never_starves_a_bucket():
    sched = Scheduler(quantum_chunks=1)
    long_spec = serve_spec(seed=0, length=4, sweeps=(8, 16))
    short_spec = serve_spec(seed=0, length=6, sweeps=(8,))
    sched.submit(long_spec)
    sched.submit(short_spec)
    sched.run_until_idle()
    sig_long = shape_signature(long_spec)[0]
    sig_short = shape_signature(short_spec)[0]
    log = sched.quantum_log
    assert set(log) == {sig_long, sig_short}
    # while both buckets are live, quanta strictly alternate (FIFO requeue)
    n_short = log.count(sig_short)
    while_both = log[: 2 * n_short]
    assert all(a != b for a, b in zip(while_both, while_both[1:]))
    # the long bucket still finished after the short one drained
    assert log[-1] == sig_long


# -- failure isolation ---------------------------------------------------------


def test_failing_tenant_does_not_take_down_its_bucket():
    sched = Scheduler(quantum_chunks=1)

    def explode(job, update):
        if update.sweeps_done >= 8:
            raise RuntimeError("tenant bug")

    seeds = (0, 1, 2)
    bad = sched.submit(serve_spec(seed=seeds[0]), on_update=explode)
    good = [sched.submit(serve_spec(seed=s)) for s in seeds[1:]]
    sched.run_until_idle()
    assert bad.state is JobState.FAILED
    with pytest.raises(JobFailedError, match="tenant bug"):
        bad.result(timeout=0)
    for job, seed in zip(good, seeds[1:]):
        assert job.state is JobState.DONE
        assert_job_matches_solo(
            job.result(timeout=5), serve_spec(seed=seed)
        )


# -- lifecycle / service mode --------------------------------------------------


def test_job_lifecycle_states_and_background_thread():
    sched = Scheduler(quantum_chunks=1)
    job = sched.submit(serve_spec(seed=8))
    assert job.state is JobState.PENDING
    sched.start()
    try:
        result = sched.result(job, timeout=60)
    finally:
        sched.shutdown()
    assert job.state is JobState.DONE
    assert result.n_sweeps == 16
    assert job.n_updates > 0
    assert job.last_update.sweeps_done == 16
    assert_job_matches_solo(result, serve_spec(seed=8))


def test_result_manifest_is_jsonable():
    import json

    sched = Scheduler()
    job = sched.submit(serve_spec(seed=3))
    sched.run_until_idle()
    manifest = job.result(timeout=5).manifest()
    round_tripped = json.loads(json.dumps(manifest, sort_keys=True))
    assert round_tripped["job"] == job.id
    assert round_tripped["n_sweeps"] == 16
    assert RunSpec.from_dict(round_tripped["spec"]) == serve_spec(seed=3)
