"""The pluggable replica-exchange subsystem (DESIGN.md §Exchange).

Property tests (shared `conftest.py` hypothesis strategies) for the swap
layer's structural invariants — every strategy's pairing is a valid
involution, the logistic rule is Barker-complementary, Metropolis satisfies
the detailed-balance identity — plus integration checks: `deo` is bit-equal
to the pre-strategy `swap_permutation` path, `vmpt` realizes the *same
chain* as `deo` while Rao-Blackwellizing the estimator through the stats
weight channel, and the flow-optimized ladder mode consumes the `flow_up`
diagnostic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising, ladder, swap
from repro.engine import AdaptConfig, Engine, EngineConfig, init_stats, update_stats
from repro.engine.adapt import AdaptState, flow_optimized_ladder, maybe_adapt
from repro.exchange import (
    DEO,
    SEO,
    VMPT,
    Windowed,
    available_strategies,
    make_strategy,
)

R, L = 6, 8
TEMPS = np.asarray(ladder.linear_ladder(R, 1.0, 3.5))


# ---------- registry ------------------------------------------------------------
def test_registry_covers_expected_strategies():
    assert set(available_strategies()) == {"deo", "seo", "windowed", "vmpt"}
    assert isinstance(make_strategy(None), DEO)  # default
    assert make_strategy("windowed", {"window": 6}) == Windowed(window=6)
    with pytest.raises(ValueError, match="unknown exchange strategy"):
        make_strategy("qpam")
    with pytest.raises(ValueError, match="window"):
        Windowed(window=1)


# ---------- structural invariants -----------------------------------------------
@pytest.mark.parametrize("name", sorted(["deo", "seo", "windowed", "vmpt"]))
def test_strategy_involutions_deterministic_grid(name):
    """Bare-environment (no hypothesis) cover of the involution invariant:
    every strategy's pairing is self-inverse with no rung paired twice."""
    params = {"window": 3} if name == "windowed" else {}
    strategy = make_strategy(name, params)
    for n in (2, 3, 5, 8, 13):
        for phase in range(4):
            for seed in range(3):
                key = jax.random.key(seed)
                p = np.asarray(strategy.propose_pairs(key, jnp.int32(phase), n))
                np.testing.assert_array_equal(p[p], np.arange(n))


def test_every_strategy_proposes_involutions():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    from conftest import exchange_strategies

    @hyp.given(
        strategy=exchange_strategies(),
        n=st.integers(2, 33),
        phase=st.integers(0, 5),
        seed=st.integers(0, 2**16),
    )
    @hyp.settings(max_examples=60, deadline=None)
    def check(strategy, n, phase, seed):
        key = jax.random.key(seed)
        p = np.asarray(strategy.propose_pairs(key, jnp.int32(phase), n))
        # self-inverse permutation => a valid pairing: no rung in two pairs
        np.testing.assert_array_equal(p[p], np.arange(n))
        if isinstance(strategy, (DEO, SEO, VMPT)):
            assert np.all(np.abs(p - np.arange(n)) <= 1)  # neighbours only
        if isinstance(strategy, Windowed):
            # pairs stay within one window (measured on the ladder ring —
            # the shifted grid wraps once)
            d = np.abs(p - np.arange(n))
            assert np.all(np.minimum(d, n - d) < strategy.window)

    check()


def test_deo_bit_equal_to_seed_swap_permutation():
    """The extracted default must reproduce `swap_permutation` exactly."""
    deo = DEO()
    betas = jnp.asarray(1.0 / TEMPS, jnp.float32)
    for seed in range(5):
        key = jax.random.key(seed)
        e = jax.random.normal(jax.random.fold_in(key, 9), (R,)) * 30
        for phase in range(4):
            ref = swap.swap_permutation(key, jnp.int32(phase), betas, e, n=R)
            partner = deo.propose_pairs(key, jnp.int32(phase), R)
            got = deo.accept(key, partner, betas, e)
            for a, b in zip(ref, got):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("seed", range(4))
def test_logistic_acceptance_is_barker_complementary(seed):
    """p(i,j) + p(j,i) = 1 for the logistic rule, over random pair data."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    n = 16
    betas = jnp.sort(jax.random.uniform(k1, (n,), minval=0.1, maxval=2.0))[::-1]
    e = jax.random.normal(k2, (n,)) * 40
    p = swap.swap_probability(betas[:-1], betas[1:], e[:-1], e[1:], "logistic")
    q = swap.swap_probability(betas[:-1], betas[1:], e[1:], e[:-1], "logistic")
    np.testing.assert_allclose(np.asarray(p + q), 1.0, rtol=1e-5)


def test_metropolis_satisfies_detailed_balance_identity():
    """p(i,j) / p(j,i) = exp(Δβ·ΔE): the ratio that makes the extended-
    ensemble chain reversible, checked in the regime below the clamp."""
    db = np.asarray([0.01, 0.1, 0.5, 1.5])
    de = np.asarray([-40.0, -3.0, -0.1, 0.0, 0.1, 3.0, 40.0])
    for dbi in db:
        for dei in de:
            blo, bhi = jnp.float32(1.0 + dbi), jnp.float32(1.0)
            elo, ehi = jnp.float32(dei), jnp.float32(0.0)
            fwd = swap.swap_probability(blo, bhi, elo, ehi, "metropolis")
            rev = swap.swap_probability(blo, bhi, ehi, elo, "metropolis")
            arg = float((blo - bhi) * (elo - ehi))
            np.testing.assert_allclose(
                float(fwd) / float(rev), np.exp(arg), rtol=1e-4
            )


def test_vmpt_weights_are_a_distribution_per_rung():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st
    from conftest import rung_energies, temp_ladders

    vmpt = VMPT()

    @hyp.given(
        temps=temp_ladders(min_rungs=2, max_rungs=12),
        data=st.data(),
        seed=st.integers(0, 2**16),
        phase=st.integers(0, 3),
    )
    @hyp.settings(max_examples=30, deadline=None)
    def check(temps, data, seed, phase):
        n = len(temps)
        e = jnp.asarray(data.draw(rung_energies(n)))
        betas = jnp.asarray(1.0 / np.asarray(temps), jnp.float32)
        key = jax.random.key(seed)
        partner = vmpt.propose_pairs(key, jnp.int32(phase), n)
        _, _, prob, _ = vmpt.accept(key, partner, betas, e)
        w = np.asarray(vmpt.estimator_weights(partner, prob))
        assert w.shape == (2, n)
        assert np.all(w >= 0) and np.all(w <= 1)
        np.testing.assert_allclose(w.sum(axis=0), 1.0, rtol=1e-6)
        # unpaired rungs keep their configuration with certainty
        unpaired = np.asarray(partner) == np.arange(n)
        np.testing.assert_array_equal(w[1][unpaired], 0.0)

    check()


# ---------- engine integration ---------------------------------------------------
def _engine(strategy, **kw):
    system = ising.IsingSystem(length=L)
    cfg = EngineConfig(
        n_replicas=R, swap_interval=5, chunk_intervals=3, exchange=strategy, **kw
    )
    return Engine(system, cfg, observables={
        "am": lambda s: jnp.abs(ising.magnetization(s))
    })


@pytest.mark.parametrize("strategy", ["seo", "windowed", "vmpt"])
def test_strategies_run_and_keep_rung_permutation_valid(strategy):
    eng = _engine(strategy)
    st = eng.init(jax.random.key(1), TEMPS)
    st, res = eng.run(st, 60)
    assert sorted(np.asarray(st.pt.rung).tolist()) == list(range(R))
    assert np.isfinite(res.summary["mean_energy"]).all()
    # weights sum to one per record, so weight_sum tracks n_records exactly
    np.testing.assert_allclose(
        np.asarray(st.stats.weight_sum), float(np.asarray(st.stats.n_records))
    )


def test_vmpt_realizes_the_same_chain_as_deo():
    """Waste recycling changes the estimator, never the chain: states, rungs
    and energies must be bit-identical to a DEO run with the same seed."""
    e_deo = _engine("deo")
    e_vm = _engine("vmpt")
    st_d = e_deo.init(jax.random.key(2), TEMPS)
    st_v = e_vm.init(jax.random.key(2), TEMPS)
    st_d, res_d = e_deo.run(st_d, 100)
    st_v, res_v = e_vm.run(st_v, 100)
    np.testing.assert_array_equal(np.asarray(st_d.pt.states), np.asarray(st_v.pt.states))
    np.testing.assert_array_equal(np.asarray(st_d.pt.energy), np.asarray(st_v.pt.energy))
    np.testing.assert_array_equal(np.asarray(st_d.pt.rung), np.asarray(st_v.pt.rung))
    # ...while the waste-recycled means differ (they mix in virtual states)
    assert not np.array_equal(
        res_d.summary["mean_energy"], res_v.summary["mean_energy"]
    )


def test_vmpt_trace_carries_the_virtual_outcome_axis():
    eng = _engine("vmpt", record_trace=True)
    st = eng.init(jax.random.key(3), TEMPS)
    st, res = eng.run(st, 30)  # 6 intervals
    assert res.trace["energy"].shape == (6, 2, R)
    assert res.trace["est_weight"].shape == (6, 2, R)
    np.testing.assert_allclose(res.trace["est_weight"].sum(axis=1), 1.0, rtol=1e-6)
    assert res.trace["swap_attempt"].shape == (6, R)


def test_weighted_welford_matches_mixture_mean():
    """The stats weight channel must reproduce the closed-form weighted mean
    (and the plain path when every weight is 1)."""
    rng = np.random.default_rng(0)
    r, t = 4, 30
    vals = rng.normal(size=(t, 2, r)).astype(np.float32)
    w1 = rng.uniform(0, 1, size=(t, r)).astype(np.float32)
    weights = np.stack([1.0 - w1, w1], axis=1)  # (t, 2, r)
    s = init_stats(r, ["energy"])
    diag = {
        "swap_accept": jnp.zeros((r,), bool),
        "swap_prob": jnp.zeros((r,)),
        "swap_attempt": jnp.zeros((r,), bool),
    }
    for i in range(t):
        rec = {"energy": jnp.asarray(vals[i]),
               "est_weight": jnp.asarray(weights[i]), **diag}
        s = update_stats(s, rec, jnp.arange(r, dtype=jnp.int32))
    expect = (vals * weights).sum(axis=(0, 1)) / weights.sum(axis=(0, 1))
    np.testing.assert_allclose(np.asarray(s.mean["energy"]), expect, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s.weight_sum), t, rtol=1e-5)


def test_retune_resets_weight_sum_with_the_moments():
    """A mid-run ladder retune restarts the moment accumulators — weight_sum
    is part of that state.  Regression: a stale total deflates post-retune
    variances and freezes the weighted (VMPT) mean updates near zero."""
    eng = _engine("vmpt")
    eng.adapt = AdaptConfig(target=0.4, min_attempts_per_pair=2)
    st = eng.init(jax.random.key(7), TEMPS)
    st, res = eng.run(st, 200)
    assert len(res.ladder_history) > 1  # a retune actually fired
    np.testing.assert_allclose(
        np.asarray(st.stats.weight_sum), float(np.asarray(st.stats.n_records))
    )
    # the post-retune weighted means track the live energies, not zero
    e_rung = np.asarray(st.pt.energy)[np.argsort(np.asarray(st.pt.rung))]
    assert np.all(np.abs(res.summary["mean_energy"] - e_rung) < 60.0)


# ---------- flow-optimized ladders ----------------------------------------------
def test_flow_optimized_ladder_concentrates_rungs_at_the_bottleneck():
    """A sharp f(T) drop in one gap is a mixing bottleneck: the optimized
    ladder must place more rungs (smaller spacings) there."""
    temps = np.linspace(1.0, 4.0, 7)
    f = np.asarray([1.0, 0.98, 0.96, 0.94, 0.25, 0.02, 0.0])  # cliff at gap 3->4
    new = flow_optimized_ladder(temps, f, rate=1.0)
    assert new.shape == temps.shape
    np.testing.assert_allclose(new[0], temps[0], rtol=1e-6)
    np.testing.assert_allclose(new[-1], temps[-1], rtol=1e-6)
    assert np.all(np.diff(new) > 0)
    gaps = np.diff(new)
    # the cliff lived between the original rungs 3 and 4 (T in [2.5, 3.0]);
    # the smallest new gap must fall inside that region
    k = int(np.argmin(gaps))
    assert 2.4 <= new[k] and new[k + 1] <= 3.1, new


def test_flow_optimized_ladder_survives_degenerate_gap():
    """Regression: an earlier aggressive retune can leave two interior rungs
    coincident; the unfloored gap then made η infinite, the cum-integral
    normalization turned every rung NaN, and the poisoned betas (traced
    engine inputs) silently corrupted the rest of the run.  A degenerate gap
    must attract ~no rung density and the retune must stay finite/monotone."""
    temps = np.asarray([1.0, 2.0, 2.0, 2.7, 3.5])
    f = np.asarray([1.0, 0.6, 0.6, 0.3, 0.0])
    new = flow_optimized_ladder(temps, f, rate=1.0)
    assert np.all(np.isfinite(new))
    np.testing.assert_allclose(new[0], temps[0], rtol=1e-6)
    np.testing.assert_allclose(new[-1], temps[-1], rtol=1e-6)
    assert np.all(np.diff(new) >= 0)
    # partially blended retunes stay finite too
    assert np.all(np.isfinite(flow_optimized_ladder(temps, f, rate=0.5)))
    # fully collapsed interior: still finite, endpoints pinned
    flat = np.asarray([1.0, 2.0, 2.0, 2.0, 3.5])
    out = flow_optimized_ladder(flat, f, rate=1.0)
    assert np.all(np.isfinite(out))
    assert out[0] == 1.0 and out[-1] == 3.5


def test_maybe_adapt_flow_mode_gates_and_consumes_flow_counters():
    temps = np.linspace(1.0, 4.0, 5)
    adapt = AdaptConfig(mode="flow", flow_min_visits=10, rate=1.0)
    st = AdaptState.fresh(5)
    counters = {
        "attempts": np.full(5, 100.0), "accepts": np.full(5, 30.0),
        "up": np.asarray([9.0, 7.0, 5.0, 3.0, 0.0]),
        "labeled": np.full(5, 9.0),  # below the gate
    }
    new, fb = maybe_adapt(temps, counters, adapt, st)
    assert new is None and fb is None and st.rounds == 0
    counters["labeled"] = np.full(5, 20.0)
    counters["up"] = np.asarray([20.0, 15.0, 10.0, 5.0, 0.0])
    new, fb = maybe_adapt(temps, counters, adapt, st)
    assert new is not None and st.rounds == 1
    np.testing.assert_allclose(fb, counters["up"] / 20.0)
    # window rebased: an identical second call has zero fresh signal
    new2, _ = maybe_adapt(temps, counters, adapt, st)
    assert new2 is None


def test_flow_adapt_end_to_end_improves_or_matches_round_trips():
    """Flow-optimized feedback must actually fire through the engine loop and
    keep the ladder valid (monotone, endpoints pinned)."""
    system = ising.IsingSystem(length=L)
    cfg = EngineConfig(n_replicas=R, swap_interval=2, chunk_intervals=50, n_chains=2)
    eng = Engine(system, cfg, adapt=AdaptConfig(mode="flow", flow_min_visits=5, rate=0.8))
    st = eng.init(jax.random.key(5), np.asarray(ladder.linear_ladder(R, 1.0, 4.0)))
    st, res = eng.run(st, 600)
    assert len(res.ladder_history) > 1  # the flow feedback fired
    final = res.ladder_history[-1]
    assert np.all(np.diff(final) > 0)
    np.testing.assert_allclose(final[0], 1.0, rtol=1e-5)
    np.testing.assert_allclose(final[-1], 4.0, rtol=1e-4)


def test_flow_mode_requires_temp_swap_mode():
    system = ising.IsingSystem(length=L)
    cfg = EngineConfig(n_replicas=R, swap_interval=2, swap_mode="state")
    with pytest.raises(ValueError, match="flow"):
        Engine(system, cfg, adapt=AdaptConfig(mode="flow"))


# ---------- spec-layer integration ----------------------------------------------
def test_session_resolves_strategies_by_name():
    from repro.api import (
        ExchangeSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, Session,
        SystemSpec, EngineSpec,
    )

    base = dict(
        system=SystemSpec("ising", {"length": 4, "accept_rule": "glauber"}),
        ladder=LadderSpec(kind="custom", n_replicas=4, temps=(1.5, 2.2, 3.1, 4.4)),
        engine=EngineSpec(swap_interval=5, chunk_intervals=4),
        schedule=ScheduleSpec(phases=(PhaseSpec(name="m", n_sweeps=20),)),
        seed=2,
    )
    # default spec == explicit deo spec, bit-for-bit
    r_default = Session(RunSpec(**base)).run()
    r_deo = Session(RunSpec(exchange=ExchangeSpec(strategy="deo"), **base)).run()
    np.testing.assert_array_equal(r_default.final_energies(), r_deo.final_energies())
    for strat in ("seo", "windowed", "vmpt"):
        out = Session(RunSpec(exchange=ExchangeSpec(strategy=strat), **base)).run()
        assert np.isfinite(out.final_energies()).all()
