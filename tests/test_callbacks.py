"""Session callback-pipeline output contracts (`repro.api.session`).

`ProgressCallback` and `TraceWriterCallback` predate the telemetry layer
and are the human-facing half of observability: progress lines a user
tails, trace chunks a user post-processes.  Pinned here:

* **progress lines** — phase banners, rate-limited per-chunk sweep lines
  (``every`` honoured, final chunk always printed), retune lines with the
  rounded ladder — all on the injected stream, nothing on stdout;
* **trace streaming** — one ``trace_<phase>_<chunk>.npz`` per chunk whose
  arrays concatenate to exactly the monolithic ``RunResult.trace``, and the
  ``consumes_trace`` flag keeps the engine from buffering a duplicate;
* **early stop** — `EarlyStopCallback` truncates the schedule and marks the
  result, and downstream callbacks still see the partial phase.
"""
import io
import os

import numpy as np
import pytest

from repro.api import (
    AdaptSpec,
    EarlyStopCallback,
    EngineSpec,
    LadderSpec,
    PhaseSpec,
    ProgressCallback,
    RunSpec,
    ScheduleSpec,
    Session,
    SystemSpec,
    TraceWriterCallback,
)


def _spec(record_trace=False, adapt=None, phases=None):
    return RunSpec(
        system=SystemSpec("ising", {"length": 4}),
        ladder=LadderSpec(kind="geometric", n_replicas=4, t_min=1.5, t_max=3.5),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2,
                          record_trace=record_trace),
        schedule=ScheduleSpec(phases=tuple(
            phases or (PhaseSpec("burn", 8), PhaseSpec("measure", 8)),
        )),
        observables=("mag",),
        adapt=adapt,
        seed=0,
    )


# ---------- ProgressCallback ----------------------------------------------------


def test_progress_lines_phase_banner_and_chunks():
    out = io.StringIO()
    Session(_spec(), callbacks=[ProgressCallback(stream=out)]).run()
    lines = out.getvalue().splitlines()
    # each 8-sweep phase runs 2 chunks of 2 intervals (4 sweeps each)
    assert lines == [
        "[burn] 8 sweeps",
        "[burn] sweep 4/8",
        "[burn] sweep 8/8",
        "[measure] 8 sweeps",
        "[measure] sweep 4/8",
        "[measure] sweep 8/8",
    ]


def test_progress_every_rate_limits_but_final_chunk_prints():
    out = io.StringIO()
    spec = _spec(phases=(PhaseSpec("burn", 24),))  # 6 chunks
    Session(spec, callbacks=[ProgressCallback(every=4, stream=out)]).run()
    sweep_lines = [l for l in out.getvalue().splitlines() if "sweep " in l]
    # chunk 4 (every=4) and chunk 6 (the final chunk, always printed)
    assert sweep_lines == ["[burn] sweep 16/24", "[burn] sweep 24/24"]


def test_progress_adapt_line_shows_retuned_ladder():
    out = io.StringIO()
    spec = _spec(
        adapt=AdaptSpec(mode="acceptance", min_attempts_per_pair=1),
        phases=(PhaseSpec("burn", 32, adapt=True),),
    )
    Session(spec, callbacks=[ProgressCallback(stream=out)]).run()
    retunes = [l for l in out.getvalue().splitlines() if "retune" in l]
    assert retunes, "adaptive phase produced no retune lines"
    assert retunes[0].startswith("[burn] ladder retune #1: T = [")


def test_progress_defaults_to_stderr(capsys):
    Session(_spec(), callbacks=[ProgressCallback()]).run()
    captured = capsys.readouterr()
    assert "[burn] 8 sweeps" in captured.err
    assert captured.out == ""


# ---------- TraceWriterCallback -------------------------------------------------


def test_trace_writer_streams_chunks_that_reassemble(tmp_path):
    # reference: the monolithic trace from a run without the writer
    ref = Session(_spec(record_trace=True)).run()

    d = str(tmp_path / "chunks")
    cb = TraceWriterCallback(d)
    res = Session(_spec(record_trace=True), callbacks=[cb]).run()
    # consumes_trace: the engine must NOT also buffer the full trace
    assert res.final.trace is None

    files = sorted(os.listdir(d))
    assert files == [
        "trace_burn_000001.npz", "trace_burn_000002.npz",
        "trace_measure_000001.npz", "trace_measure_000002.npz",
    ]
    for phase in ("burn", "measure"):
        chunks = [
            np.load(os.path.join(d, f))
            for f in files if f.startswith(f"trace_{phase}_")
        ]
        ref_trace = ref.phases[phase].trace
        for key in ref_trace:
            streamed = np.concatenate([c[key] for c in chunks], axis=0)
            np.testing.assert_array_equal(streamed, ref_trace[key], err_msg=key)


def test_trace_writer_without_record_trace_writes_nothing(tmp_path):
    d = str(tmp_path / "chunks")
    Session(_spec(record_trace=False), callbacks=[TraceWriterCallback(d)]).run()
    assert os.listdir(d) == []


# ---------- EarlyStopCallback ---------------------------------------------------


def test_early_stop_truncates_schedule():
    stop_after = 4

    res = Session(
        _spec(),
        callbacks=[EarlyStopCallback(lambda info: info.sweeps_done >= stop_after)],
    ).run()
    assert res.stopped_early
    assert list(res.phases) == ["burn"]
    assert res.phases["burn"].n_sweeps == stop_after
