"""Statistical conformance: the engine vs exact ground truth, per system.

One parametrized case per `repro.core.systems.REGISTRY` entry — every system
in the zoo (Ising, Gaussian, Potts, EA spin glass, HP protein) runs through
the *production* path (chunked streaming engine, adaptive ladder ON,
``n_chains > 1`` ensemble axis ON) and its sampled ⟨E⟩ + order-parameter
means must match exact enumeration / analytic values within 4x the
batch-means MCSE at every rung of the final adapted ladder
(`repro.validate.conformance`, DESIGN.md §Validate).

Entries whose exact reference costs > ~10 s (`entry.slow`) ride the `slow`
tier so tier-1 latency stays flat; `pytest -m slow` runs them.
"""
import numpy as np
import pytest

from repro.core import systems
from repro.validate import assert_conforms, run_conformance
from repro.validate import exact as exact_lib
from repro.validate.conformance import EXACT

CASES = [
    pytest.param(name, marks=pytest.mark.slow if entry.slow else [])
    for name, entry in sorted(systems.REGISTRY.items())
]


def test_registry_covers_expected_zoo():
    """The zoo the paper motivates: lattice benchmark (Ising), multimodal
    toy (Gaussian), beyond-Ising lattice (Potts), disordered (EA), and the
    protein-folding workload (HP) — each with an exact reference."""
    assert set(systems.REGISTRY) == {
        "ising",
        "gaussian",
        "potts",
        "ea_spin_glass",
        "hp_protein",
    }
    assert set(EXACT) == set(systems.REGISTRY)
    for entry in systems.REGISTRY.values():
        assert entry.n_chains > 1  # ensemble axis always exercised
        assert entry.adapt_rounds > 0  # adaptive ladder always exercised


@pytest.mark.parametrize("name", CASES)
def test_engine_conforms_to_exact_reference(name):
    entry = systems.REGISTRY[name]
    report = run_conformance(entry, seed=0)
    # The adaptive machinery must have actually fired during burn-in.
    assert report.n_retunes == entry.adapt_rounds, report.n_retunes
    # Endpoints stay pinned; interior rungs may move.
    np.testing.assert_allclose(report.temps[0], entry.temps[0], rtol=1e-5)
    np.testing.assert_allclose(report.temps[-1], entry.temps[-1], rtol=1e-4)
    assert np.all(np.diff(report.temps) > 0)
    assert_conforms(report, z_max=4.0, geweke_max=4.0)
    # Batch-means machinery sanity: every series carries real information.
    for k, ess in report.ess.items():
        assert np.all(ess > 10), (k, ess)


def test_hp_move_graph_ergodic_at_registered_length():
    """The HP conformance answer is only exact if end+corner moves reach the
    whole SAW space at the registered chain length — check it, don't assume."""
    n = systems.REGISTRY["hp_protein"].make().n_monomers
    assert exact_lib.hp_move_graph_connected(n)


@pytest.mark.slow
def test_hp_occupancy_chi_square_exact_distribution():
    """Strongest equality-in-distribution check: thinned MH samples of a tiny
    HP chain must occupy the *full* 100-conformation space with Boltzmann
    frequencies (chi-square over every state, not just moment matching)."""
    import jax
    import jax.numpy as jnp

    from repro.core import hp
    from repro.validate import exact as exact_mod

    system = hp.HPChain(sequence="HHPHH", moves_per_step=50)  # 50 ~ >> IAT
    saws = exact_mod.enumerate_saws(4)
    key_of = {tuple(map(tuple, p)): i for i, p in enumerate(saws)}
    e = np.asarray(jax.vmap(system.energy)(jnp.asarray(saws, jnp.int32)))
    w = np.exp(-e / 1.0)
    w /= w.sum()

    walkers, records, burn = 128, 220, 20
    pos = jax.vmap(system.init_state)(jax.random.split(jax.random.key(0), walkers))
    beta = jnp.ones((walkers,))
    step = jax.jit(jax.vmap(system.mcmc_step, in_axes=(0, 0, 0)))
    counts = np.zeros(len(saws))
    key = jax.random.key(1)
    for t in range(records):
        key, sub = jax.random.split(key)
        pos, _, _ = step(jax.random.split(sub, walkers), pos, beta)
        if t >= burn:
            arr = np.asarray(pos)
            arr = arr - arr[:, :1]  # normalize translation
            for i in range(walkers):
                counts[key_of[tuple(map(tuple, arr[i]))]] += 1
    n = counts.sum()
    assert np.all(counts > 0)  # ergodic: every conformation visited
    chi2 = float(((counts - w * n) ** 2 / (w * n)).sum())
    dof = len(saws) - 1
    # ~1e-4 tail of chi2_99 with near-iid (thinned) samples
    assert chi2 < 1.65 * dof, (chi2, dof)


@pytest.mark.parametrize("strategy", ["seo", "windowed", "vmpt"])
@pytest.mark.parametrize("name", ["gaussian", "ising"])
def test_exchange_strategy_conforms_to_exact_reference(name, strategy):
    """The strategy × system gate (DESIGN.md §Exchange): every non-default
    replica-exchange scheme must be *statistically verified* on the Ising +
    Gaussian zoo entries — same adaptive ensemble path, same 4×MCSE
    tolerance — not just run without crashing.  (`deo` is the default the
    rest of this module already gates.)"""
    entry = systems.REGISTRY[name]
    report = run_conformance(entry, seed=0, exchange=strategy)
    assert report.n_retunes == entry.adapt_rounds, report.n_retunes
    np.testing.assert_allclose(report.temps[0], entry.temps[0], rtol=1e-5)
    np.testing.assert_allclose(report.temps[-1], entry.temps[-1], rtol=1e-4)
    assert np.all(np.diff(report.temps) > 0)
    assert_conforms(report, z_max=4.0, geweke_max=4.0)


def test_sharded_engine_conforms_to_exact_reference():
    """The sharded-mega-step entry in the conformance matrix (DESIGN.md
    §Distributed): the same zoo entry, executed through the shard_map path
    via ``mesh=`` (1x1 here — tier-1 has one device; the multi-device mesh
    is bit-equal to it by tests/test_distributed.py), must clear the same
    exact-reference gate as every other sampler variant."""
    from repro.core.distributed import MeshSpec

    entry = systems.REGISTRY["ising"]
    report = run_conformance(
        entry, seed=0, mesh=MeshSpec(ensemble=1, replica=1)
    )
    assert report.n_retunes == entry.adapt_rounds, report.n_retunes
    assert_conforms(report, z_max=4.0, geweke_max=4.0)


@pytest.mark.parametrize("name", [
    "ising",
    # the Potts exact reference enumerates 3^16 configs (~20 s) — same slow
    # tier as the base Potts entry
    pytest.param("potts", marks=pytest.mark.slow),
])
def test_fused_kernel_conforms_to_exact_reference(name):
    """The interval-fused kernel gate (DESIGN.md §6): fusing all
    sweeps-per-interval into one launch replaces the per-sweep `jax.random`
    uniforms with the in-kernel counter PRNG, so the chain *cannot* be
    bit-equal to the per-sweep path — it must instead be statistically
    verified against exact ground truth through the same adaptive ensemble
    path and 4×MCSE tolerance as every other sampler variant."""
    entry = systems.REGISTRY[name]
    report = run_conformance(
        entry, seed=0,
        system_params={"use_fused": True, "use_pallas": True},
    )
    assert report.n_retunes == entry.adapt_rounds, report.n_retunes
    np.testing.assert_allclose(report.temps[0], entry.temps[0], rtol=1e-5)
    np.testing.assert_allclose(report.temps[-1], entry.temps[-1], rtol=1e-4)
    assert np.all(np.diff(report.temps) > 0)
    assert_conforms(report, z_max=4.0, geweke_max=4.0)


def test_round_fused_kernel_conforms_to_exact_reference():
    """The whole-round kernel gate (DESIGN.md §6): folding the exchange into
    the launch replaces the engine's ``fold_in(key, 2t+1)`` swap draw with
    the counter PRNG's swap stream, so like ``use_fused`` it cannot be
    bit-equal to the strategy path and must clear the statistical gate —
    with ``pack_bits=True`` riding along, since packing is pinned bitwise
    elsewhere and this is its end-to-end conformance entry."""
    entry = systems.REGISTRY["ising"]
    report = run_conformance(
        entry, seed=0,
        system_params={"use_fused": True, "use_pallas": True,
                       "use_fused_round": True, "pack_bits": True},
    )
    assert report.n_retunes == entry.adapt_rounds, report.n_retunes
    np.testing.assert_allclose(report.temps[0], entry.temps[0], rtol=1e-5)
    np.testing.assert_allclose(report.temps[-1], entry.temps[-1], rtol=1e-4)
    assert np.all(np.diff(report.temps) > 0)
    assert_conforms(report, z_max=4.0, geweke_max=4.0)


def test_conformance_catches_a_wrong_sampler():
    """Negative control: a deliberately biased reference must fail the gate —
    otherwise the 4xMCSE tolerance is too loose to mean anything."""
    entry = systems.REGISTRY["ising"]

    def biased_exact(system, temps):
        out = exact_lib.ising_exact(system, temps)
        out["energy"] = out["energy"] + 1.0  # ~ >> 4 MCSE at this run length
        return out

    report = run_conformance(entry, seed=0, exact_fn=biased_exact)
    with pytest.raises(AssertionError, match="disagrees"):
        assert_conforms(report)
